"""Zero-sync hot path (ISSUE 2): steady-state async steps perform no
blocking host syncs and no pending rebuild; the pending slot never drops
an apply; metrics drain off the hot path; segmented shardings keep their
leading-dim specs; the prefetch loader is deterministic and restartable.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced_config
from repro.core.zen_optimizer import ZenFlowConfig
from repro.data import make_train_stream
from repro.distributed import zen_spmd
from repro.distributed.sharding import DEFAULT_RULES
from repro.models import build_model
from repro.runtime import RuntimeConfig, ZenFlowRuntime
from repro.telemetry import MetricsDrain, syncwatch


def _mk_runtime(zcfg, rcfg=None):
    cfg = reduced_config(get_config("llama2-7b"))
    model = build_model(cfg)
    rt = ZenFlowRuntime(model, zcfg, DEFAULT_RULES,
                        rcfg or RuntimeConfig())
    return cfg, model, rt


def _batch(cfg, loader):
    return {k: jnp.asarray(v) for k, v in loader.next_batch().items()}


# ---------------------------------------------------------------------------
# The zero-sync steady-state contract


def test_steady_state_steps_zero_blocking_syncs():
    """Inside a window the async step must dispatch without a single
    blocking host sync, without touching the pending slot, and return
    metrics as device arrays."""
    zcfg = ZenFlowConfig(topk_ratio=0.1, update_interval=8,
                         refresh_interval=8, lr=1e-3, use_kernels="never")
    cfg, model, rt = _mk_runtime(zcfg)
    rt.init(jax.random.PRNGKey(0))
    loader = make_train_stream(cfg.vocab, 32, 8)
    for _ in range(3):                      # compile + settle (t=1..3 < S)
        rt.step(_batch(cfg, loader))
    syncwatch.reset()
    for _ in range(4):                      # t=4..7: all steady-state
        m = rt.step(_batch(cfg, loader))
        assert m["boundary"] is False
        assert m["stall"] == 0.0
        assert rt.pending is None           # no zero_pending rebuild
    assert syncwatch.total() == 0, syncwatch.counts()
    assert isinstance(m["loss"], jax.Array)     # device-array metrics
    assert isinstance(m["rho"], jax.Array)
    rt.close()


def test_blocking_metrics_mode_counts_legacy_syncs():
    """RuntimeConfig.blocking_metrics restores the pre-rewrite contract:
    >= 2 forced host syncs per step, all visible to syncwatch."""
    zcfg = ZenFlowConfig(topk_ratio=0.1, update_interval=8,
                         refresh_interval=8, lr=1e-3, use_kernels="never")
    cfg, model, rt = _mk_runtime(zcfg, RuntimeConfig(blocking_metrics=True))
    rt.init(jax.random.PRNGKey(0))
    loader = make_train_stream(cfg.vocab, 32, 8)
    rt.step(_batch(cfg, loader))
    syncwatch.reset()
    m = rt.step(_batch(cfg, loader))
    assert syncwatch.total() >= 2, syncwatch.counts()
    assert isinstance(m["loss"], float)     # legacy scalarization
    rt.close()


# ---------------------------------------------------------------------------
# Pending slot: no apply is ever dropped (pre-rewrite "never leak one" bug)


def test_pending_slot_never_drops_an_apply():
    """Two queued applies on the single pending slot: the older must land
    in params through the boundary-path scatter before the newer takes
    the slot — previously the older rows were silently overwritten."""
    zcfg = ZenFlowConfig(topk_ratio=0.1, update_interval=2,
                         refresh_interval=4, lr=1e-3, use_kernels="never")
    cfg, model, rt = _mk_runtime(zcfg)
    rt.init(jax.random.PRNGKey(0))
    spec = model.param_specs()
    z = zen_spmd.zero_pending(rt.segs, spec)
    rows0 = {p: jnp.full_like(r, 3.0) for p, r in z["rows"].items()}
    rows1 = {p: jnp.full_like(r, 5.0) for p, r in z["rows"].items()}
    idx = z["idx"]

    rt._push_pending(rows0, idx)
    assert rt.pending is not None
    rt._push_pending(rows1, idx)            # must land rows0, not drop it

    # older rows landed in params at their indices...
    from repro.core.partition import tree_to_pathdict
    p0 = next(iter(rt.segs))
    pseg = zen_spmd.to_segmented(tree_to_pathdict(rt.params), rt.segs)
    from repro.core import selection as sel
    landed = sel.gather_rows(pseg[p0], idx[p0])
    np.testing.assert_allclose(np.asarray(landed, np.float32), 3.0)
    # ...and the newer ones occupy the slot for the next step
    # (pending_view unpacks the coalesced slot back to its logical layout)
    np.testing.assert_allclose(
        np.asarray(rt.pending_view()["rows"][p0], np.float32), 5.0)
    rt.close()


def test_warmup_landing_after_restore_keeps_pending():
    """A restored checkpoint's valid pending plus an immediate warmup
    landing is the end-to-end shape of the leak: both updates survive."""
    zcfg = ZenFlowConfig(topk_ratio=0.1, update_interval=2,
                         refresh_interval=4, warmup_steps=2, lr=1e-3,
                         use_kernels="never")
    cfg, model, rt = _mk_runtime(zcfg)
    rt.init(jax.random.PRNGKey(0))
    loader = make_train_stream(cfg.vocab, 32, 8)
    # warmup step: lands synchronously -> pending occupied
    rt.step(_batch(cfg, loader))
    assert rt.pending is not None
    before = rt.params
    # second warmup step: consumes the pending (boundary variant), then
    # pushes its own landing; nothing raises, slot again occupied
    rt.step(_batch(cfg, loader))
    assert rt.pending is not None
    assert before is not rt.params
    rt.close()


# ---------------------------------------------------------------------------
# Explicit host staging of host_bound


def test_stage_to_host_places_leaves_on_host_memory():
    """Every staged leaf ends up on the host memory kind; leaves already
    resident there pass through untouched (no copy on XLA:CPU, a real
    async PCIe hop on GPU/TPU)."""
    from repro.distributed.offload import host_memory_kind, stage_to_host
    kind = host_memory_kind()
    if kind is None:
        pytest.skip("no host-addressable memory kind on this backend")
    tree = {"g": jnp.arange(8.0), "flag": jnp.zeros((), jnp.bool_)}
    staged = stage_to_host(tree)
    for k, v in staged.items():
        assert v.sharding.memory_kind == kind, (k, v.sharding)
    np.testing.assert_array_equal(np.asarray(staged["g"]),
                                  np.asarray(tree["g"]))


# ---------------------------------------------------------------------------
# MetricsDrain


def test_metrics_drain_materializes_in_order():
    d = MetricsDrain(capacity=4)
    for i in range(10):
        d.push(i, {"loss": jnp.asarray(float(i)), "note": "x"})
    d.drain()
    assert [s for s, _ in d.history] == list(range(10))
    assert d.history[3][1]["loss"] == 3.0
    assert isinstance(d.history[3][1]["loss"], float)
    assert d.history[3][1]["note"] == "x"       # non-arrays pass through
    assert d.latest()[0] == 9


def test_metrics_drain_ring_bounds_inflight():
    d = MetricsDrain(capacity=2, keep_history=False)
    seen = []
    d.on_metrics = lambda step, m: seen.append(step)
    for i in range(6):
        d.push(i, {"v": jnp.asarray(1.0 * i)})
    assert len(d) <= 2
    d.drain()
    assert seen == list(range(6))
    assert d.history == []


# ---------------------------------------------------------------------------
# segmented_sharding carries lead_spec (satellite fix)


def test_segmented_sharding_carries_lead_spec():
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    seg = zen_spmd.SegmentInfo(
        path="layers/w", row_shards=1, m_local=4, quota=1,
        row_axis_spec=None, col_axis_spec="model", lead_spec=("data",))
    # value arrays (lead..., RS, X, n): lead dim keeps its axis
    sh = zen_spmd.segmented_sharding("layers/w", seg, 4, mesh)
    assert sh.spec == P("data", None, None, "model"), sh.spec
    # index arrays (lead..., RS, X)
    sh2 = zen_spmd.segmented_sharding("layers/w", seg, 3, mesh, core=2)
    assert sh2.spec == P("data", None, None), sh2.spec
    # no leading dims: unchanged behavior
    sh3 = zen_spmd.segmented_sharding("layers/w", seg, 3, mesh)
    assert sh3.spec == P(None, None, "model"), sh3.spec


# ---------------------------------------------------------------------------
# PrefetchLoader


def test_prefetch_loader_matches_plain_and_restores():
    l1 = make_train_stream(100, 16, 8)
    l2 = make_train_stream(100, 16, 8, prefetch=2)
    try:
        for _ in range(5):
            np.testing.assert_array_equal(
                l1.next_batch()["tokens"],
                np.asarray(l2.next_batch()["tokens"]))
        assert l2.state() == l1.state()
        l3 = make_train_stream(100, 16, 8, prefetch=3)
        try:
            l3.restore(l2.state())
            np.testing.assert_array_equal(
                l1.next_batch()["tokens"],
                np.asarray(l3.next_batch()["tokens"]))
        finally:
            l3.close()
    finally:
        l2.close()


def test_prefetch_loader_propagates_producer_errors():
    """A failing wrapped loader must surface its error to next_batch()
    instead of silently killing the producer thread and deadlocking;
    a closed loader raises instead of hanging."""
    from repro.data import PrefetchLoader

    class Boom:
        def next_batch(self):
            raise ValueError("boom")

        def state(self):
            return {"step": 0}

    pl = PrefetchLoader(Boom(), depth=1, to_device=False)
    with pytest.raises(RuntimeError, match="producer failed"):
        pl.next_batch()
    pl.close()

    l2 = make_train_stream(100, 16, 4, prefetch=1)
    l2.next_batch()
    l2.close()
    with pytest.raises(RuntimeError, match="stopped"):
        l2.next_batch()


def test_load_state_dict_drops_inflight_apply():
    """Restoring over a live runtime must not let a pre-restore host
    apply land its rows into the restored params."""
    zcfg = ZenFlowConfig(topk_ratio=0.1, update_interval=2,
                         refresh_interval=4, lr=1e-3, use_kernels="never")
    cfg, model, rt = _mk_runtime(zcfg)
    rt.init(jax.random.PRNGKey(0))
    # deep-copy like a checkpoint write would: the live buffers are
    # donated by subsequent host accumulates
    sd0 = jax.tree.map(jnp.array, rt.state_dict())
    loader = make_train_stream(cfg.vocab, 32, 8)
    for _ in range(3):                  # boundary at t=2 submits an apply
        rt.step(_batch(cfg, loader))
    rt.load_state_dict(sd0)             # roll back without flush()
    assert rt._apply_future is None     # stale apply dropped
    assert rt._t == 0
    m = rt.step(_batch(cfg, loader))    # restored run steps cleanly
    assert bool(np.isfinite(np.asarray(m["loss"])))
    rt.close()


def test_prefetch_loader_overlaps_construction():
    """After the queue fills, next_batch() pops without re-sampling."""
    loader = make_train_stream(512, 64, 8, prefetch=2)
    try:
        first = loader.next_batch()          # may wait for the producer
        time.sleep(0.2)                      # queue refills in background
        t0 = time.perf_counter()
        loader.next_batch()
        assert time.perf_counter() - t0 < 0.1
        assert "tokens" in first
    finally:
        loader.close()
